"""The fault-injection gauntlet (DESIGN §2.7, the CI ``chaos`` job).

Each scenario injects a fault at a documented engine seam via
:class:`repro.serve.FaultPlan` and asserts the hardened serving tier's
contract: **every fault surfaces as a typed error or a degraded-but-
correct answer — never a silent wrong one**.

Scenario matrix:

1. corrupted ``bvss_spmm`` tile  → verify-mode catches, session is
   quarantined, queries re-serve correctly on the reference path;
2. NaN-poisoned σ channel        → the finite guard degrades betweenness
   to the host Brandes oracle;
3. stalled shard in the frontier all-gather (mesh session) → verify-mode
   catches the under-discovery, degraded-but-correct re-serve;
4. over-quota request            → AdmissionError with a reason code;
5. expired deadline              → partial TimeoutResult / typed
   DeadlineExceeded, never a hang;
6. corrupted push tile (DESIGN §2.8 direction-optimizing hybrid) →
   a fault that only fires on push levels still cannot slip a silent
   wrong answer past full verification.
"""
import warnings

import numpy as np
import pytest

from conftest import require_devices
from repro.core import reference_bfs
from repro.errors import AdmissionError, DeadlineExceeded
from repro.graphs import generators as gen
from repro.kernels.ref import betweenness_ref
from repro.serve import (DegradedServiceWarning, FaultPlan, GraphSession,
                         GraphSessionManager, NO_FAULTS, TenantQuota,
                         TimeoutResult)

QUERIES = [0, 5, 19, 64]


@pytest.fixture(scope="module")
def graph():
    return gen.rmat(7, 8, seed=2)


# ---------------------------------------------------------------------------
# plan plumbing
# ---------------------------------------------------------------------------
def test_no_fault_plan_is_free():
    assert not NO_FAULTS.injects
    assert NO_FAULTS.engine_overrides() == {}
    plan = FaultPlan(corrupt_spmm_tile=True)
    assert plan.injects
    assert set(plan.engine_overrides()) == {"spmm_impl"}
    push = FaultPlan(corrupt_push_tile=True)
    assert push.injects
    assert set(push.engine_overrides()) == {"push_impl"}
    assert set(push.engine_overrides(use_kernel=False)) == {"push_impl"}
    both = FaultPlan(nan_sigma=True, stall_shard=1)
    assert set(both.engine_overrides()) == {"spmm_w_impl", "gather_impl"}
    stage = FaultPlan(stall_butterfly_stage=0)
    assert stage.injects
    assert set(stage.engine_overrides()) == {"gather_impl"}


def test_double_stall_plan_rejected():
    """``stall_shard`` and ``stall_butterfly_stage`` both occupy the
    ``gather_impl`` seam — a plan setting both is a configuration bug
    and must be refused at construction, not silently last-writer-wins."""
    from repro.errors import ConfigError
    with pytest.raises(ConfigError, match="gather_impl"):
        FaultPlan(stall_shard=0, stall_butterfly_stage=1)


def test_faulted_session_actually_diverges(graph):
    """Sanity for the gauntlet itself: the corrupt-tile fault DOES change
    answers (otherwise scenario 1 would be vacuous)."""
    sess = GraphSession(graph, max_batch=2,
                        fault_plan=FaultPlan(corrupt_spmm_tile=True))
    diverged = sum(
        not np.array_equal(lv, reference_bfs(graph, q))
        for q, lv in zip(QUERIES, sess.levels_batch(QUERIES)))
    assert diverged > 0


# ---------------------------------------------------------------------------
# scenario 1: corrupted bit-SpMM tile
# ---------------------------------------------------------------------------
def test_corrupt_tile_quarantined_and_reserved_correctly(graph):
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("bad", graph, max_batch=2,
                     fault_plan=FaultPlan(corrupt_spmm_tile=True))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mgr.levels_batch("bad", QUERIES)
    # caller still gets CORRECT levels (reference re-serve) ...
    for q, lv in zip(QUERIES, out):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))
    # ... with a structured warning and a quarantine on the books
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    st = mgr.stats()
    assert st["quarantines"] == 1
    assert st["degraded_serves"] >= 1
    rec = mgr._sessions["bad"]
    assert rec.quarantined and "diverge" in rec.quarantine_reason
    # subsequent calls skip the faulty engine entirely
    for q, lv in zip(QUERIES, mgr.levels_batch("bad", QUERIES)):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))


def test_unverified_faulty_session_is_the_counterfactual(graph):
    """verify_fraction=0 knowingly serves the corruption — documenting
    that the sampling policy (not luck) is what closes the hole."""
    mgr = GraphSessionManager(verify_fraction=0.0)
    mgr.open_session("bad", graph, max_batch=2,
                     fault_plan=FaultPlan(corrupt_spmm_tile=True))
    out = mgr.levels_batch("bad", QUERIES)
    assert any(not np.array_equal(lv, reference_bfs(graph, q))
               for q, lv in zip(QUERIES, out))
    assert mgr.stats()["quarantines"] == 0


# ---------------------------------------------------------------------------
# scenario 2: NaN-poisoned sigma channel (weighted Brandes path)
# ---------------------------------------------------------------------------
def test_nan_sigma_degrades_betweenness_to_oracle(graph):
    mgr = GraphSessionManager()
    mgr.open_session("poisoned", graph, max_batch=2,
                     fault_plan=FaultPlan(nan_sigma=True))
    srcs = [0, 5, 19]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        bc = mgr.betweenness("poisoned", srcs)
    assert np.isfinite(bc).all()
    np.testing.assert_allclose(bc, betweenness_ref(graph, srcs), rtol=1e-6)
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    st = mgr.stats()
    assert st["quarantines"] == 1
    assert "σ" in mgr._sessions["poisoned"].quarantine_reason
    # the quarantine also protects the plain level verbs afterwards
    for q, lv in zip(QUERIES, mgr.levels_batch("poisoned", QUERIES)):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))


def test_nan_sigma_fault_actually_poisons(graph):
    sess = GraphSession(graph, max_batch=2,
                        fault_plan=FaultPlan(nan_sigma=True))
    bc = sess.betweenness([0, 5])
    assert not np.isfinite(bc).all()


# ---------------------------------------------------------------------------
# scenario 3: stalled shard in the frontier-word all-gather (mesh)
# ---------------------------------------------------------------------------
def test_stalled_shard_caught_and_reserved_correctly(graph):
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("stalled", graph, max_batch=2, mesh=bfs_mesh(2),
                     fault_plan=FaultPlan(stall_shard=1))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mgr.levels_batch("stalled", QUERIES)
    for q, lv in zip(QUERIES, out):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    assert mgr.stats()["quarantines"] == 1


def test_stalled_shard_fault_actually_underdiscovers(graph):
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    sess = GraphSession(graph, max_batch=2, mesh=bfs_mesh(2),
                        fault_plan=FaultPlan(stall_shard=1))
    diverged = sum(
        not np.array_equal(lv, reference_bfs(graph, q))
        for q, lv in zip(QUERIES, sess.levels_batch(QUERIES)))
    assert diverged > 0


# ---------------------------------------------------------------------------
# scenario 3b: stalled butterfly stage (2-D mesh, PR-8 partner-block drop)
# ---------------------------------------------------------------------------
def test_stalled_butterfly_stage_caught_and_reserved_correctly(graph):
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh2d
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("dark", graph, max_batch=2, mesh=bfs_mesh2d(2, 1),
                     fault_plan=FaultPlan(stall_butterfly_stage=0))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mgr.levels_batch("dark", QUERIES)
    for q, lv in zip(QUERIES, out):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    assert mgr.stats()["quarantines"] == 1


def test_stalled_butterfly_stage_fault_actually_underdiscovers(graph):
    """Sanity: dropping the stage-0 partner block DOES change answers.
    The seam is consulted by the wave pool, so the probe rides
    ``levels_batch`` (singleton ``levels`` serves off the unfaulted fused
    engine by design — the seam is the exchange, not the query verb)."""
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh2d
    sess = GraphSession(graph, max_batch=2, mesh=bfs_mesh2d(2, 1),
                        fault_plan=FaultPlan(stall_butterfly_stage=0))
    diverged = sum(
        not np.array_equal(lv, reference_bfs(graph, q))
        for q, lv in zip(QUERIES, sess.levels_batch(QUERIES)))
    assert diverged > 0


# ---------------------------------------------------------------------------
# scenario 4: over-quota request is refused, not queued
# ---------------------------------------------------------------------------
def test_over_quota_rejected_with_reason(graph):
    mgr = GraphSessionManager(
        default_quota=TenantQuota(max_sessions=1, max_inflight=2))
    mgr.open_session("g", graph, max_batch=2)
    with pytest.raises(AdmissionError) as ei:
        mgr.open_session("g2", graph, max_batch=2)
    assert ei.value.reason == "tenant-sessions"
    with pytest.raises(AdmissionError) as ei:
        mgr.levels_batch("g", [0, 1, 2])
    assert ei.value.reason == "inflight"
    # the session itself is untouched by the rejections
    np.testing.assert_array_equal(mgr.levels("g", 0),
                                  reference_bfs(graph, 0))


# ---------------------------------------------------------------------------
# scenario 5: expired deadline degrades, never hangs
# ---------------------------------------------------------------------------
def test_expired_deadline_partial_or_typed_error(graph):
    mgr = GraphSessionManager()
    mgr.open_session("g", graph, max_batch=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mgr.levels_batch("g", QUERIES, deadline_s=0.0)
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    for q, r in zip(QUERIES, out):
        assert isinstance(r, TimeoutResult) and not r.complete
        ref = reference_bfs(graph, q)
        got = r.levels != np.int32(np.iinfo(np.int32).max)
        # the partial prefix is still oracle-exact
        np.testing.assert_array_equal(r.levels[got], ref[got])
    with pytest.raises(DeadlineExceeded):
        mgr.levels_batch("g", QUERIES, deadline_s=0.0, on_deadline="raise")


# ---------------------------------------------------------------------------
# scenario 6: corrupted push tile (hybrid direction, DESIGN §2.8)
# ---------------------------------------------------------------------------
def test_corrupt_push_fault_actually_diverges(graph):
    """Sanity: under ``direction="push"`` every level runs the push
    kernel, so the corrupt tile DOES change answers (the fused singleton
    engine is the seam's consumer — build-time injection, no retrace)."""
    sess = GraphSession(graph, use_kernel=False, direction="push",
                        fault_plan=FaultPlan(corrupt_push_tile=True))
    diverged = sum(
        not np.array_equal(sess.levels(q), reference_bfs(graph, q))
        for q in QUERIES)
    assert diverged > 0


def test_corrupt_push_invisible_on_pull_levels(graph):
    """The push fault must NOT leak into pull traffic: a pull-forced
    session built with the same plan stays oracle-exact — the fault is
    direction-scoped, which is exactly why it needs its own scenario."""
    sess = GraphSession(graph, use_kernel=False, direction="pull",
                        fault_plan=FaultPlan(corrupt_push_tile=True))
    for q in QUERIES:
        np.testing.assert_array_equal(sess.levels(q),
                                      reference_bfs(graph, q))


def test_corrupt_push_quarantined_and_reserved_correctly(graph):
    """Full gauntlet: singleton queries ride the fused push engine, the
    verify sampler catches the divergence, the session quarantines and
    every answer the caller sees is oracle-exact."""
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("pushy", graph, use_kernel=False, direction="push",
                     fault_plan=FaultPlan(corrupt_push_tile=True))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = [mgr.levels("pushy", q) for q in QUERIES]
    for q, lv in zip(QUERIES, out):
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    st = mgr.stats()
    assert st["quarantines"] == 1
    assert mgr._sessions["pushy"].quarantined


# ---------------------------------------------------------------------------
# the gauntlet property: zero silent wrong answers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("plan", [
    FaultPlan(corrupt_spmm_tile=True),
    FaultPlan(nan_sigma=True),
], ids=["corrupt-tile", "nan-sigma"])
def test_no_silent_wrong_answers(graph, plan):
    """Under full verification every COMPLETE answer the manager returns
    equals the oracle, fault or no fault — the central robustness claim."""
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("s", graph, max_batch=2, fault_plan=plan)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DegradedServiceWarning)
        levels = mgr.levels_batch("s", QUERIES)
        bc = mgr.betweenness("s", QUERIES)
    for q, lv in zip(QUERIES, levels):
        if isinstance(lv, TimeoutResult):
            continue
        np.testing.assert_array_equal(lv, reference_bfs(graph, q))
    assert np.isfinite(bc).all()
    np.testing.assert_allclose(bc, betweenness_ref(graph, QUERIES),
                               rtol=1e-6)
