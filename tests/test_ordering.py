"""Ordering heuristics: validity, and the paper's §3.2 effects."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import build_bvss
from repro.core.ordering import (auto_order, is_social_like, jaccard_windows,
                                 natural_order, random_order, rcm,
                                 shingle_order, social_like_report)
from repro.graphs import from_edges, generators as gen


def is_permutation(perm, n):
    return sorted(perm.tolist()) == list(range(n))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 100), m=st.integers(0, 300),
       seed=st.integers(0, 1000))
def test_orderings_are_permutations(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
    for perm in (natural_order(g), random_order(g), shingle_order(g),
                 rcm(g), jaccard_windows(g, w=64)):
        assert is_permutation(perm, n)


def test_rcm_reduces_bandwidth_on_grid():
    g = gen.grid2d(25, 25, shuffle=True, seed=1)
    bw0 = g.bandwidth()
    bw1 = g.permute_fast(rcm(g)).bandwidth()
    assert bw1 < bw0 / 5


def test_jaccard_windows_improves_compression_on_clusters():
    g = gen.clustered(20, 32, seed=2)
    c0 = build_bvss(g).compression_ratio()
    perm = jaccard_windows(g, w=256, pre_order=shingle_order(g))
    c1 = build_bvss(g.permute_fast(perm)).compression_ratio()
    assert c1 > c0 * 1.5  # paper Table 1a: large compression gains


def test_window_size_monotone_trend():
    """Fig. 3: larger windows should not hurt compression (on average)."""
    g = gen.clustered(16, 32, seed=3)
    pre = shingle_order(g)
    comps = []
    for w in (32, 128, 512):
        perm = jaccard_windows(g, w=w, pre_order=pre)
        comps.append(build_bvss(g.permute_fast(perm)).compression_ratio())
    assert comps[-1] >= comps[0]


def test_social_classifier():
    assert is_social_like(gen.rmat(10, 16, seed=4))          # scale-free
    assert not is_social_like(gen.grid2d(32, 32))            # road-like
    rep = social_like_report(gen.rmat(10, 16, seed=4))
    assert rep.heavy_tail or rep.power_law


def test_auto_order_policy():
    _, kind_soc = auto_order(gen.rmat(9, 16, seed=5), w=256)
    _, kind_road = auto_order(gen.grid2d(20, 20), w=256)
    assert kind_soc == "jaccard_windows"
    assert kind_road == "rcm"
