"""Ordering heuristics: validity, and the paper's §3.2 effects."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import build_bvss
from repro.core.ordering import (auto_order, is_social_like, jaccard_windows,
                                 natural_order, random_order, rcm,
                                 shingle_order, social_like_report)
from repro.graphs import from_edges, generators as gen


def is_permutation(perm, n):
    return sorted(perm.tolist()) == list(range(n))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 100), m=st.integers(0, 300),
       seed=st.integers(0, 1000))
def test_orderings_are_permutations(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
    for perm in (natural_order(g), random_order(g), shingle_order(g),
                 rcm(g), jaccard_windows(g, w=64)):
        assert is_permutation(perm, n)


def test_rcm_reduces_bandwidth_on_grid():
    g = gen.grid2d(25, 25, shuffle=True, seed=1)
    bw0 = g.bandwidth()
    bw1 = g.permute_fast(rcm(g)).bandwidth()
    assert bw1 < bw0 / 5


def test_jaccard_windows_improves_compression_on_clusters():
    g = gen.clustered(20, 32, seed=2)
    c0 = build_bvss(g).compression_ratio()
    perm = jaccard_windows(g, w=256, pre_order=shingle_order(g))
    c1 = build_bvss(g.permute_fast(perm)).compression_ratio()
    assert c1 > c0 * 1.5  # paper Table 1a: large compression gains


def test_window_size_monotone_trend():
    """Fig. 3: larger windows should not hurt compression (on average)."""
    g = gen.clustered(16, 32, seed=3)
    pre = shingle_order(g)
    comps = []
    for w in (32, 128, 512):
        perm = jaccard_windows(g, w=w, pre_order=pre)
        comps.append(build_bvss(g.permute_fast(perm)).compression_ratio())
    assert comps[-1] >= comps[0]


def test_social_classifier():
    assert is_social_like(gen.rmat(10, 16, seed=4))          # scale-free
    assert not is_social_like(gen.grid2d(32, 32))            # road-like
    rep = social_like_report(gen.rmat(10, 16, seed=4))
    assert rep.heavy_tail or rep.power_law


def test_auto_order_policy():
    _, kind_soc = auto_order(gen.rmat(9, 16, seed=5), w=256)
    _, kind_road = auto_order(gen.grid2d(20, 20), w=256)
    assert kind_soc == "jaccard_windows"
    assert kind_road == "rcm"


# ---------------------------------------------------------------------------
# direct classifier coverage (the "One Ordering Decision" policy exercised
# on SYNTHETIC degree structure, outside the generator/end-to-end path)
# ---------------------------------------------------------------------------
def synthetic_power_law(n=600, alpha=2.5, seed=7):
    """Configuration-style graph from an explicit power-law OUT-degree
    sequence: deterministic, generator-independent heavy tail (the
    uniform in-degrees dilute but don't break the log-log fit)."""
    rng = np.random.default_rng(seed)
    # inverse-CDF sample of a discrete power law, capped at n/4
    u = rng.random(n)
    deg = np.minimum((u ** (-1.0 / (alpha - 1.0))).astype(np.int64),
                     n // 4)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, len(src))
    return from_edges(n, src, dst)


def synthetic_hubs(n=400, k=8, deg_bg=3, seed=11):
    """A few all-reaching hubs over a sparse background: the mass-
    concentration (heavy-tail) arm of the classifier, with a degree
    histogram too degenerate for the power-law fit."""
    rng = np.random.default_rng(seed)
    hub_src = np.repeat(np.arange(k), n - k)
    hub_dst = np.tile(np.arange(k, n), k)
    bg_src = np.repeat(np.arange(k, n), deg_bg)
    bg_dst = rng.integers(0, n, len(bg_src))
    return from_edges(n, np.concatenate([hub_src, bg_src]),
                      np.concatenate([hub_dst, bg_dst]))


def test_social_like_report_on_synthetic_power_law():
    rep = social_like_report(synthetic_power_law())
    assert rep.is_social
    # the explicit degree sequence must light up the power-law detector:
    # a straight log-log fit with the paper's slope range
    assert rep.power_law
    assert -4.0 <= rep.ll_slope <= -1.2
    assert rep.ll_r2 >= 0.7


def test_social_like_report_on_synthetic_hubs():
    rep = social_like_report(synthetic_hubs())
    assert rep.is_social
    # this triggers the OTHER arm: top-percentile mass, not the fit
    assert rep.heavy_tail
    assert rep.top1_share > 0.05 and rep.top10_share > 0.40
    assert not rep.power_law


def test_social_like_report_on_grid_fields():
    rep = social_like_report(gen.grid2d(24, 24))
    assert not rep.is_social
    # uniform degrees: no mass concentration in the top percentiles…
    assert rep.top1_share < 0.05
    assert rep.top10_share < 0.40
    # …and a degenerate degree histogram can't pass the straight-line fit
    assert not rep.power_law


def test_is_social_like_direct_split():
    assert is_social_like(synthetic_power_law())
    assert is_social_like(synthetic_hubs())
    assert not is_social_like(gen.grid2d(16, 16))
    assert not is_social_like(gen.path(200))


def test_auto_order_on_synthetic_power_law_vs_grid():
    perm_pl, kind_pl = auto_order(synthetic_power_law(n=300), w=64)
    perm_gr, kind_gr = auto_order(gen.grid2d(12, 12), w=64)
    assert kind_pl == "jaccard_windows"
    assert kind_gr == "rcm"
    assert is_permutation(perm_pl, 300)
    assert is_permutation(perm_gr, 144)
