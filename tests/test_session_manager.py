"""GraphSessionManager: admission control, tenant quotas, the
byte-budgeted LRU of prepared state, per-request deadlines with partial
TimeoutResults, verify-mode sampling, and quarantine bookkeeping
(DESIGN §2.7)."""
import warnings

import numpy as np
import pytest

from repro.core import reference_bfs
from repro.errors import (AdmissionError, DeadlineExceeded,
                          GraphValidationError)
from repro.graphs import from_edges, generators as gen
from repro.serve import (DegradedServiceWarning, GraphSessionManager,
                         TenantQuota, TimeoutResult, session_cost_bytes)

INF = np.int32(np.iinfo(np.int32).max)


@pytest.fixture(scope="module")
def rmat_graph():
    return gen.rmat(7, 8, seed=1)


# ---------------------------------------------------------------------------
# serving correctness through the manager
# ---------------------------------------------------------------------------
def test_serves_oracle_levels(rmat_graph):
    g = rmat_graph
    mgr = GraphSessionManager()
    mgr.open_session("g", g, max_batch=3)
    queries = [0, 5, 9, 20, 77]
    for q, lv in zip(queries, mgr.levels_batch("g", queries)):
        np.testing.assert_array_equal(lv, reference_bfs(g, q))
    np.testing.assert_array_equal(mgr.levels("g", 9), reference_bfs(g, 9))


def test_source_validation_through_manager(rmat_graph):
    mgr = GraphSessionManager()
    mgr.open_session("g", rmat_graph, max_batch=2)
    with pytest.raises(GraphValidationError):
        mgr.levels_batch("g", [0, -1])
    with pytest.raises(GraphValidationError):
        mgr.levels("g", rmat_graph.n)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_unknown_session_rejected(rmat_graph):
    mgr = GraphSessionManager()
    with pytest.raises(AdmissionError) as ei:
        mgr.levels_batch("nope", [0])
    assert ei.value.reason == "unknown-session"


def test_duplicate_name_rejected(rmat_graph):
    mgr = GraphSessionManager()
    mgr.open_session("g", rmat_graph, max_batch=2)
    with pytest.raises(AdmissionError) as ei:
        mgr.open_session("g", rmat_graph)
    assert ei.value.reason == "duplicate-name"


def test_tenant_isolation(rmat_graph):
    """One tenant must not see (or even probe) another's sessions."""
    mgr = GraphSessionManager()
    mgr.open_session("g", rmat_graph, tenant="alice", max_batch=2)
    with pytest.raises(AdmissionError) as ei:
        mgr.levels_batch("g", [0], tenant="bob")
    assert ei.value.reason == "unknown-session"
    # alice still works
    np.testing.assert_array_equal(
        mgr.levels("g", 0, tenant="alice"), reference_bfs(rmat_graph, 0))


def test_tenant_session_quota(rmat_graph):
    mgr = GraphSessionManager(
        default_quota=TenantQuota(max_sessions=1))
    mgr.open_session("a", rmat_graph, max_batch=2)
    with pytest.raises(AdmissionError) as ei:
        mgr.open_session("b", rmat_graph, max_batch=2)
    assert ei.value.reason == "tenant-sessions"
    # another tenant has its own allowance
    mgr.open_session("b", rmat_graph, tenant="other", max_batch=2)


def test_tenant_byte_quota(rmat_graph):
    mgr = GraphSessionManager()
    sess = mgr.open_session("probe", rmat_graph, max_batch=2)
    cost = session_cost_bytes(sess)
    mgr.close_session("probe")
    mgr.set_quota("tiny", TenantQuota(max_bytes=cost // 2))
    with pytest.raises(AdmissionError) as ei:
        mgr.open_session("a", rmat_graph, tenant="tiny", max_batch=2)
    assert ei.value.reason == "tenant-bytes"


def test_inflight_quota(rmat_graph):
    mgr = GraphSessionManager(
        default_quota=TenantQuota(max_inflight=2))
    mgr.open_session("g", rmat_graph, max_batch=2)
    with pytest.raises(AdmissionError) as ei:
        mgr.levels_batch("g", [0, 1, 2])
    assert ei.value.reason == "inflight"
    assert len(mgr.levels_batch("g", [0, 1])) == 2


# ---------------------------------------------------------------------------
# byte-budgeted LRU of prepared state
# ---------------------------------------------------------------------------
def test_lru_eviction_under_byte_budget(rmat_graph):
    g = rmat_graph
    mgr0 = GraphSessionManager()
    cost = session_cost_bytes(mgr0.open_session("probe", g, max_batch=2))

    mgr = GraphSessionManager(byte_budget=int(cost * 2.5))
    mgr.open_session("a", g, max_batch=2)
    mgr.open_session("b", g, max_batch=2)
    mgr.levels("a", 0)        # touch a: b becomes the LRU victim
    mgr.open_session("c", g, max_batch=2)
    assert "b" not in mgr and "a" in mgr and "c" in mgr
    assert mgr.stats()["evictions"] == 1
    assert mgr.bytes_used() <= mgr.byte_budget
    # evicted session can be re-opened (re-prepared) at any time
    mgr.open_session("b", g, max_batch=2)
    assert mgr.stats()["evictions"] == 2


def test_oversized_session_rejected_not_thrashed(rmat_graph):
    mgr = GraphSessionManager(byte_budget=64)
    with pytest.raises(AdmissionError) as ei:
        mgr.open_session("huge", rmat_graph, max_batch=2)
    assert ei.value.reason == "byte-budget"
    assert mgr.stats()["sessions"] == 0


def test_session_cost_uses_memory_model(rmat_graph):
    mgr = GraphSessionManager()
    sess = mgr.open_session("g", rmat_graph, max_batch=4)
    cost = session_cost_bytes(sess)
    assert cost >= sess.bvss.memory_bytes()["total"]
    assert mgr.bytes_used() == cost
    assert mgr.stats()["tenants"]["default"]["bytes"] == cost


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_expired_deadline_returns_partial(rmat_graph):
    g = rmat_graph
    mgr = GraphSessionManager()
    mgr.open_session("g", g, max_batch=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mgr.levels_batch("g", [0, 5], deadline_s=0.0)
    assert any(issubclass(x.category, DegradedServiceWarning) for x in w)
    for q, r in zip([0, 5], out):
        assert isinstance(r, TimeoutResult)
        assert not r.complete
        ref = reference_bfs(g, q)
        # partial levels: every computed level matches the oracle...
        got = r.levels != INF
        np.testing.assert_array_equal(r.levels[got], ref[got])
        # ...and the frontier is the oracle's depth-d shell
        np.testing.assert_array_equal(
            np.sort(r.frontier), np.flatnonzero(ref == r.depth))
    assert mgr.stats()["timeouts"] == 2


def test_deadline_partial_progress_by_level():
    """A long path graph with a 0s deadline is harvested after ONE
    lock-step level — the documented cancellation granularity."""
    g = from_edges(60, np.arange(59), np.arange(1, 60))
    mgr = GraphSessionManager()
    mgr.open_session("path", g, max_batch=2, order=False)
    [r] = mgr.levels_batch("path", [0], deadline_s=0.0)
    assert isinstance(r, TimeoutResult)
    assert r.depth == 1                       # one level, then harvested
    assert int((r.levels != INF).sum()) == 2  # source + one neighbour


def test_deadline_raise_mode(rmat_graph):
    mgr = GraphSessionManager()
    mgr.open_session("g", rmat_graph, max_batch=2)
    with pytest.raises(DeadlineExceeded):
        mgr.levels_batch("g", [0, 5], deadline_s=0.0, on_deadline="raise")


def test_generous_deadline_serves_complete(rmat_graph):
    g = rmat_graph
    mgr = GraphSessionManager()
    mgr.open_session("g", g, max_batch=2)
    out = mgr.levels_batch("g", [0, 5], deadline_s=3600.0)
    for q, lv in zip([0, 5], out):
        assert not isinstance(lv, TimeoutResult)
        np.testing.assert_array_equal(lv, reference_bfs(g, q))
    assert mgr.stats()["timeouts"] == 0


def test_deadline_does_not_block_other_queries():
    """One over-deadline deep query is harvested; a shallow query in the
    same wave still completes exactly."""
    g = from_edges(60, np.arange(59), np.arange(1, 60))
    mgr = GraphSessionManager()
    mgr.open_session("path", g, max_batch=2, order=False)
    clock = {"t": 0.0}
    mgr._clock = lambda: clock["t"]

    # budget 5 "seconds"; each level step costs 1; query 0 (depth 59)
    # must get harvested, query 58 (depth 1) completes within budget
    real = mgr._sessions["path"].session.levels_batch

    def stepping(srcs, **kw):
        orig_should = kw.get("should_harvest")

        def should(i):
            clock["t"] += 1.0
            return orig_should(i)

        if orig_should is not None:
            kw["should_harvest"] = should
        return real(srcs, **kw)

    mgr._sessions["path"].session.levels_batch = stepping
    out = mgr.levels_batch("path", [0, 58], deadline_s=5.0)
    assert isinstance(out[0], TimeoutResult)
    np.testing.assert_array_equal(out[1], reference_bfs(g, 58))


# ---------------------------------------------------------------------------
# verify-mode sampling / quarantine surface (healthy-path side; the
# fault-injection side lives in tests/test_faults.py)
# ---------------------------------------------------------------------------
def test_verify_sampling_counts(rmat_graph):
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("g", rmat_graph, max_batch=3)
    mgr.levels_batch("g", [0, 5, 9])
    st = mgr.stats()
    assert st["verified"] == 3
    assert st["quarantines"] == 0


def test_verify_fraction_validated():
    with pytest.raises(ValueError):
        GraphSessionManager(verify_fraction=1.5)
    with pytest.raises(ValueError):
        GraphSessionManager(verify_fraction=-0.1)


def test_close_session(rmat_graph):
    mgr = GraphSessionManager()
    mgr.open_session("g", rmat_graph, max_batch=2)
    mgr.close_session("g")
    assert "g" not in mgr
    assert mgr.bytes_used() == 0
    with pytest.raises(AdmissionError):
        mgr.levels("g", 0)


def test_events_and_stats_shape(rmat_graph):
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("g", rmat_graph, max_batch=2)
    mgr.levels_batch("g", [0, 5])
    mgr.close_session("g")
    kinds = {e["kind"] for e in mgr.events}
    assert {"open", "verify-pass", "close"} <= kinds
    st = mgr.stats()
    for key in ("sessions", "bytes_used", "byte_budget", "evictions",
                "timeouts", "quarantines", "rejections",
                "degraded_serves", "verified", "tenants"):
        assert key in st
