"""2-D (row × column) BVSS partition tests — PR-8 (DESIGN §2.4/§3).

Parity contract: the 2-D engines (single-source eager/lazy, wave pool,
σ channel, betweenness) must match the single-device answers on every
mesh shape — bit-exact on integer levels, ≤1e-6 relative error on the
float channels.  Multi-device cases run in subprocesses with
--xla_force_host_platform_device_count (same pattern as
tests/test_distributed.py) so the main pytest session keeps its
single-device jax instance; the butterfly collectives additionally get
direct unit tests against the flat ``all_gather`` they replace.
"""
import os
import subprocess
import sys

import pytest

from conftest import require_devices

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# butterfly collectives: unit parity vs the flat gather they replace
# ---------------------------------------------------------------------------
def test_butterfly_collectives_match_flat():
    """On power-of-two axes the staged butterfly exchange must reproduce
    the index-ordered ``all_gather`` exactly, and the OR-allreduce the
    gather+OR — for every axis size the 2-D meshes use; the stall seam
    must visibly zero the partner block (otherwise the chaos scenario is
    vacuous)."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import (butterfly_frontier_exchange,
                                           butterfly_or_allreduce)
rng = np.random.default_rng(0)
for n in (2, 4, 8):
    mesh = Mesh(np.asarray(jax.devices()[:n]), ("x",))
    words = jnp.asarray(rng.integers(0, 2**32, (8 * n, 3), dtype=np.uint32))

    def bf(seg):
        return butterfly_frontier_exchange(seg, "x")[None]
    def flat(seg):
        return jax.lax.all_gather(seg, "x", tiled=True)[None]
    kw = dict(mesh=mesh, in_specs=P("x"), out_specs=P("x"),
              check_rep=False)
    got = np.asarray(shard_map(bf, **kw)(words)).reshape(n, -1, 3)
    ref = np.asarray(shard_map(flat, **kw)(words)).reshape(n, -1, 3)
    assert (got == ref).all(), n
    # every device returns the same full gather
    assert all((got[d] == words).all() for d in range(n)), n

    def orred(seg):
        return butterfly_or_allreduce(seg, "x")[None]
    got_or = np.asarray(shard_map(orred, **kw)(words)).reshape(n, -1, 3)
    ref_or = np.bitwise_or.reduce(
        np.asarray(words).reshape(n, -1, 3), axis=0)
    assert all((got_or[d] == ref_or).all() for d in range(n)), n

    # the stall seam drops data: stage-0 stall != clean exchange
    def stalled(seg):
        return butterfly_frontier_exchange(seg, "x", stall_stage=0)[None]
    bad = np.asarray(shard_map(stalled, **kw)(words)).reshape(n, -1, 3)
    assert (bad != ref).any(), n
print("ok")
""", n_devices=8)


def test_butterfly_non_pow2_falls_back_to_flat():
    """Axis size 3: no recursive-doubling schedule exists, so both
    collectives must fall back to the flat gather — same result, and the
    ledger must label the traffic as the fallback."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import (butterfly_frontier_exchange,
                                           butterfly_or_allreduce,
                                           comm_ledger)
mesh = Mesh(np.asarray(jax.devices()[:3]), ("x",))
rng = np.random.default_rng(1)
words = jnp.asarray(rng.integers(0, 2**32, (9, 2), dtype=np.uint32))
kw = dict(mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)

def bf(seg):
    return butterfly_frontier_exchange(seg, "x")[None]
with comm_ledger() as ev:
    got = np.asarray(shard_map(jax.jit(bf), **kw)(words)).reshape(3, 9, 2)
assert all((got[d] == words).all() for d in range(3))
assert any(lab == "butterfly_fallback_flat" for lab, _ in ev), ev

def orred(seg):
    return butterfly_or_allreduce(seg, "x")[None]
with comm_ledger() as ev:
    got = np.asarray(shard_map(jax.jit(orred), **kw)(words)).reshape(3, 3, 2)
ref = np.bitwise_or.reduce(np.asarray(words).reshape(3, 3, 2), axis=0)
assert all((got[d] == ref).all() for d in range(3))
assert any(lab == "or_allreduce_fallback_flat" for lab, _ in ev), ev
print("ok")
""", n_devices=8)


def test_comm_ledger_unit():
    """Trace-time ledger semantics: records only while open, nested
    ledgers shadow, bytes sum exactly."""
    from repro.distributed.collectives import comm_ledger, record_comm
    record_comm("dropped", 999)        # no open ledger: silently ignored
    with comm_ledger() as outer:
        record_comm("a", 100)
        with comm_ledger() as inner:
            record_comm("b", 50)
        record_comm("c", 7)
    assert inner == [("b", 50)]
    assert outer == [("a", 100), ("c", 7)]
    assert sum(n for _, n in outer) == 107


# ---------------------------------------------------------------------------
# typed mesh-ingress errors (satellite: ConfigError regression tests)
# ---------------------------------------------------------------------------
def test_mesh_over_request_raises_config_error():
    from repro.distributed.bfs_dist import bfs_mesh, bfs_mesh2d
    from repro.errors import ConfigError
    import jax
    too_many = len(jax.devices()) + 1
    with pytest.raises(ConfigError, match="relaunch with XLA_FLAGS"):
        bfs_mesh(too_many)
    # ConfigError is a ValueError subclass (PR-6 typed-ingress contract),
    # so pre-PR-8 callers catching ValueError keep working
    with pytest.raises(ValueError):
        bfs_mesh(too_many)
    with pytest.raises(ConfigError):
        bfs_mesh2d(too_many, 1)


def test_mesh2d_shape_validation():
    from repro.distributed.bfs_dist import bfs_mesh2d
    from repro.errors import ConfigError
    with pytest.raises(ConfigError, match="positive"):
        bfs_mesh2d(0, 1)
    with pytest.raises(ConfigError, match="positive"):
        bfs_mesh2d(2, -1)
    # rows < cols leaves column shards without a full row block
    with pytest.raises(ConfigError, match="rows >= cols"):
        bfs_mesh2d(1, 2)


def test_2d_forced_push_rejected():
    """The 2-D engines are pull-only: the interleaved column partition
    has no per-device push operand, so forcing ``direction="push"`` must
    be a typed refusal, not a silent pull."""
    run_py("""
from repro.graphs import generators as gen
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh2d
from repro.errors import ConfigError
g = gen.rmat(7, 8, seed=0)
try:
    prepare(g, w=256, mesh=bfs_mesh2d(2, 2), direction="push")
except ConfigError as e:
    assert "pull" in str(e).lower(), e
else:
    raise AssertionError("direction='push' must be rejected on 2-D meshes")
# "auto" on 2-D quietly resolves to pull and still answers correctly
from repro.core import reference_bfs
pb = prepare(g, w=256, mesh=bfs_mesh2d(2, 2), direction="auto")
assert (pb.levels(0) == reference_bfs(g, 0)).all()
print("ok")
""", n_devices=4)


# ---------------------------------------------------------------------------
# level parity: single-source engines across mesh shapes (ragged n)
# ---------------------------------------------------------------------------
def test_2d_prepare_matches_oracle_across_meshes():
    """The core acceptance sweep: a ragged clustered graph (n=69 — no
    alignment is natural) through eager and lazy engines on {1×1, 2×1,
    2×2, 4×2}; every mesh must be bit-exact with the host oracle."""
    run_py("""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh2d
g = gen.clustered(3, 23, seed=4)
srcs = (0, g.n // 3, g.n - 1)
ref = {s: reference_bfs(g, s) for s in srcs}
for rows, cols in ((1, 1), (2, 1), (2, 2), (4, 2)):
    mesh = bfs_mesh2d(rows, cols)
    for eng in ("blest", "blest_lazy"):
        pb = prepare(g, w=256, mesh=mesh, engine=eng)
        for s in srcs:
            assert (pb.levels(s) == ref[s]).all(), (rows, cols, eng, s)
print("ok")
""", n_devices=8)


def test_2d_isolated_sources_and_empty_columns():
    """Degenerate frontiers: isolated vertices (instant termination),
    and a sparse graph whose frontier occupies a single column block for
    entire levels — empty column segments must stay inert, not wedge the
    OR-allreduce or the liveness reduction."""
    run_py("""
import numpy as np
from repro.graphs import from_edges, generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh2d
# 50 vertices, 3 edges: vertex 0 (and most others) isolated
g = from_edges(50, [1, 2, 10], [2, 3, 11])
mesh = bfs_mesh2d(2, 2)
pb = prepare(g, w=256, order=False, mesh=mesh)
for s in (0, 1, 10, 49):
    assert (pb.levels(s) == reference_bfs(g, s)).all(), s
# long path: every level's frontier is ONE vertex — all but one column
# segment empty at every level, on both mesh shapes
n = 70
gp = from_edges(n, np.arange(n - 1), np.arange(1, n))
for rows, cols in ((2, 2), (4, 2)):
    pb = prepare(gp, w=256, order=False, mesh=bfs_mesh2d(rows, cols))
    for s in (0, n - 1, n // 2):
        assert (pb.levels(s) == reference_bfs(gp, s)).all(), (rows, cols, s)
print("ok")
""", n_devices=8)


# ---------------------------------------------------------------------------
# wave pool + σ channel parity (float channels ≤ 1e-6 rel err)
# ---------------------------------------------------------------------------
def test_2d_session_transparency_and_sigma_parity():
    """GraphSession(g, mesh=2-D) must serve every verb unchanged: wave
    levels bit-exact, betweenness/closeness within 1e-6 of the host
    references — ordering and the 2-D shard layout invisible to
    callers."""
    run_py("""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.kernels.ref import betweenness_ref
from repro.serve import GraphSession
from repro.distributed.bfs_dist import bfs_mesh2d
g = gen.clustered(3, 23, seed=4)
single = GraphSession(g, max_batch=3, w=256)
for rows, cols in ((2, 2), (4, 2)):
    sess = GraphSession(g, max_batch=3, w=256, mesh=bfs_mesh2d(rows, cols))
    queries = [0, 7, 23, 7, g.n - 1]
    for q, lv in zip(queries, sess.levels_batch(queries)):
        np.testing.assert_array_equal(lv, reference_bfs(g, q),
                                      err_msg=f"{rows}x{cols} query {q}")
    srcs = [0, 5, 23, 41]
    bc = sess.betweenness(srcs)
    np.testing.assert_allclose(bc, betweenness_ref(g, srcs), rtol=1e-6,
                               err_msg=f"{rows}x{cols} betweenness")
    np.testing.assert_allclose(bc, single.betweenness(srcs), rtol=1e-6)
    np.testing.assert_array_equal(sess.components(), single.components())
print("ok")
""", n_devices=8)


# ---------------------------------------------------------------------------
# in-process 4×2 parity — the BLEST_REQUIRE_MULTIDEVICE=1 CI anchor
# ---------------------------------------------------------------------------
def test_2d_parity_in_process():
    """Runs in the multidevice CI job's own 8-device process (no
    subprocess indirection) so the job provably exercises the 2-D path:
    ``require_devices(8)`` FAILS rather than skips under
    BLEST_REQUIRE_MULTIDEVICE=1."""
    require_devices(8)
    import numpy as np

    from repro.core import reference_bfs
    from repro.core.policy import prepare
    from repro.distributed.bfs_dist import bfs_mesh2d
    from repro.graphs import generators as gen
    g = gen.rmat(7, 8, seed=2)
    pb = prepare(g, w=256, mesh=bfs_mesh2d(4, 2))
    for s in (0, g.n // 2, g.n - 1):
        np.testing.assert_array_equal(pb.levels(s), reference_bfs(g, s))
