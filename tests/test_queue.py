"""RequestQueue / WaveScheduler: the async serving half (DESIGN §2.10).

Covers the PR-10 queue contract end to end: non-blocking submits with
future resolution, mid-flight wave coalescing, tenant-fair slot hand-out
under quota pressure, bounded ingress (global + per-tenant backlog),
deadline harvests into partial TimeoutResults, the background pump, and
draining under injected faults (degraded-but-correct, never wrong) or a
vanished session (futures fail loudly, never dangle).
"""
import threading
import time

import numpy as np
import pytest

from repro import (FaultPlan, GraphSessionManager, PrepareOptions,
                   QueueFullError, RequestQueue, TenantQuota, TimeoutResult)
from repro.core import reference_bfs
from repro.graphs import generators as gen


@pytest.fixture(scope="module")
def graph():
    return gen.rmat(7, 8, seed=5)


@pytest.fixture(scope="module")
def refs(graph):
    return {s: reference_bfs(graph, s) for s in range(graph.n)}


def _mgr(graph, name="g", *, tenant="default", verify_fraction=0.0,
         max_batch=4, **mgr_kwargs):
    mgr = GraphSessionManager(verify_fraction=verify_fraction, **mgr_kwargs)
    mgr.open_session(name, graph, tenant=tenant, max_batch=max_batch,
                     options=PrepareOptions(w=512))
    return mgr


# ---------------------------------------------------------------------------
# basic contract: submit is non-blocking, drain resolves every future
# ---------------------------------------------------------------------------
def test_submit_drain_resolves_correct_levels(graph, refs):
    q = RequestQueue(_mgr(graph))
    srcs = [0, 3, 9, 27, 50, 81, 100, 5]
    futs = [q.submit("g", s) for s in srcs]
    assert not any(f.done() for f in futs)        # nothing ran yet
    n = q.drain()
    assert n == len(srcs)
    for s, f in zip(srcs, futs):
        assert f.done() and f.exception(0) is None
        np.testing.assert_array_equal(f.result(0), refs[s])
    st = q.stats()
    assert st["submitted"] == st["completed"] == len(srcs)
    assert st["pending"] == 0 and st["timeouts"] == 0
    # 8 requests through a 4-slot pool: later arrivals joined in-flight
    # waves (the whole point of the queue)
    assert st["coalesced"] > 0
    assert st["waves"] >= 1


def test_same_source_twice_resolves_both(graph, refs):
    q = RequestQueue(_mgr(graph))
    f1, f2 = q.submit("g", 7), q.submit("g", 7)
    q.drain()
    np.testing.assert_array_equal(f1.result(0), refs[7])
    np.testing.assert_array_equal(f2.result(0), refs[7])


def test_submit_validates_at_ingress(graph):
    q = RequestQueue(_mgr(graph))
    with pytest.raises(Exception):      # bad source: rejected at submit,
        q.submit("g", graph.n + 5)      # not at drain
    with pytest.raises(Exception):      # unknown session
        q.submit("nope", 0)
    assert q.pending == 0


# ---------------------------------------------------------------------------
# bounded ingress
# ---------------------------------------------------------------------------
def test_capacity_rejects_with_reason(graph):
    q = RequestQueue(_mgr(graph), capacity=3)
    for s in range(3):
        q.submit("g", s)
    with pytest.raises(QueueFullError) as ei:
        q.submit("g", 4)
    assert ei.value.reason == "capacity"
    assert q.stats()["rejected"] == 1
    q.drain()                            # backlog still serves fine
    assert q.pending == 0


def test_tenant_backlog_rejects_only_the_hog(graph):
    mgr = GraphSessionManager()
    mgr.open_session("a", graph, tenant="acme", max_batch=2,
                     options=PrepareOptions(w=512))
    mgr.open_session("b", graph, tenant="beta", max_batch=2,
                     options=PrepareOptions(w=512))
    q = RequestQueue(mgr, tenant_backlog=2)
    q.submit("a", 0, tenant="acme")
    q.submit("a", 1, tenant="acme")
    with pytest.raises(QueueFullError) as ei:
        q.submit("a", 2, tenant="acme")
    assert ei.value.reason == "tenant-backlog"
    # the other tenant is unaffected by acme's full backlog
    f = q.submit("b", 3, tenant="beta")
    q.drain()
    assert f.done()


# ---------------------------------------------------------------------------
# fairness under quota pressure
# ---------------------------------------------------------------------------
def test_tenant_fair_slot_handout_under_inflight_quota(graph, refs):
    """max_inflight=1 caps a tenant at one slot at a time: its backlog
    serializes (slots never overlap, so nothing coalesces) instead of
    monopolising the 4-wide pool — and still completes correctly."""
    mgr = GraphSessionManager(
        default_quota=TenantQuota(max_inflight=1))
    mgr.open_session("s", graph, tenant="hog", max_batch=4,
                     options=PrepareOptions(w=512))
    q = RequestQueue(mgr)
    futs = [q.submit("s", s, tenant="hog") for s in range(6)]
    n = q.drain()
    assert n == 6
    # one slot at a time: no request ever joined an in-flight wave
    # (contrast test_submit_drain_resolves_correct_levels, where the
    # uncapped pool coalesces)
    assert q.stats()["coalesced"] == 0
    for s, f in zip(range(6), futs):
        np.testing.assert_array_equal(f.result(0), refs[s])


def test_multi_session_drain_is_round_robin_not_starving(graph, refs):
    """drain() serves every session with eligible work each pass — a
    session with a standing backlog cannot starve a later-registered
    one."""
    mgr = GraphSessionManager()
    mgr.open_session("first", graph, max_batch=2,
                     options=PrepareOptions(w=512))
    mgr.open_session("second", graph, max_batch=2,
                     options=PrepareOptions(w=512))
    q = RequestQueue(mgr)
    fa = [q.submit("first", s) for s in range(5)]
    fb = [q.submit("second", s) for s in range(5)]
    q.drain()
    for s, f in zip(range(5), fa):
        np.testing.assert_array_equal(f.result(0), refs[s])
    for s, f in zip(range(5), fb):
        np.testing.assert_array_equal(f.result(0), refs[s])


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_harvests_partial_timeout_result(graph):
    """A clock that jumps past the deadline mid-wave forces the harvest
    path: the future resolves to a partial TimeoutResult whose computed
    prefix MATCHES the oracle (partial, never wrong)."""
    t = {"now": 0.0}
    mgr = _mgr(graph)
    q = RequestQueue(mgr, clock=lambda: t["now"])

    fut = q.submit("g", 0, deadline_s=5.0)
    t["now"] = 10.0                      # deadline long gone before drain
    q.drain()
    res = fut.result(0)
    assert isinstance(res, TimeoutResult)
    assert res.complete is False and res.source == 0
    ref = reference_bfs(graph, 0)
    INF = np.iinfo(np.int32).max
    got = res.levels
    assert (got != INF).any() and (got == INF).any()   # genuinely partial
    mask = got != INF
    np.testing.assert_array_equal(got[mask], ref[mask])
    assert q.stats()["timeouts"] == 1


def test_generous_deadline_completes_normally(graph, refs):
    q = RequestQueue(_mgr(graph))
    fut = q.submit("g", 11, deadline_s=3600.0)
    q.drain()
    np.testing.assert_array_equal(fut.result(0), refs[11])
    assert q.stats()["timeouts"] == 0


# ---------------------------------------------------------------------------
# not_before (simulated arrivals) + background pump
# ---------------------------------------------------------------------------
def test_not_before_holds_request_until_due(graph, refs):
    t = {"now": 0.0}
    q = RequestQueue(_mgr(graph), clock=lambda: t["now"])
    fut = q.submit("g", 2, not_before=100.0)
    q.drain()                            # not due yet: nothing served
    assert not fut.done() and q.pending == 1
    t["now"] = 100.0
    q.drain()
    np.testing.assert_array_equal(fut.result(0), refs[2])


def test_background_pump_resolves_without_explicit_drain(graph, refs):
    q = RequestQueue(_mgr(graph))
    q.start(poll_s=0.001)
    try:
        futs = [q.submit("g", s) for s in (1, 2, 3)]
        for s, f in zip((1, 2, 3), futs):
            np.testing.assert_array_equal(f.result(10.0), refs[s])
    finally:
        q.stop()
    assert q.pending == 0


def test_submit_from_other_threads_is_safe(graph, refs):
    q = RequestQueue(_mgr(graph, max_batch=8))
    out: list = []

    def client(lo):
        fs = [q.submit("g", s) for s in range(lo, lo + 4)]
        out.append((lo, fs))

    threads = [threading.Thread(target=client, args=(lo,))
               for lo in (0, 10, 20)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    q.drain()
    for lo, fs in out:
        for s, f in zip(range(lo, lo + 4), fs):
            np.testing.assert_array_equal(f.result(0), refs[s])


# ---------------------------------------------------------------------------
# fault gauntlet: drain degrades, never lies, never dangles
# ---------------------------------------------------------------------------
def test_faulty_session_drains_degraded_but_correct(graph, refs):
    """verify_fraction=1 + corrupted SpMM tile: the queue's post-wave
    verify quarantines the session and every future resolves on the
    reference path — correct answers, degraded stats on the books."""
    mgr = GraphSessionManager(verify_fraction=1.0)
    mgr.open_session("bad", graph, max_batch=2,
                     options=PrepareOptions(w=512),
                     fault_plan=FaultPlan(corrupt_spmm_tile=True))
    q = RequestQueue(mgr)
    srcs = [0, 3, 9, 27]
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # DegradedServiceWarning expected
        futs = [q.submit("bad", s) for s in srcs]
        q.drain()
    for s, f in zip(srcs, futs):
        np.testing.assert_array_equal(f.result(0), refs[s])
    assert mgr.stats()["quarantines"] == 1
    assert q.stats()["degraded"] > 0
    # the NEXT batch short-circuits to the reference path (quarantined)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        f2 = q.submit("bad", 5)
        q.drain()
    np.testing.assert_array_equal(f2.result(0), refs[5])


def test_closed_session_rejects_backlog_loudly(graph):
    mgr = _mgr(graph)
    q = RequestQueue(mgr)
    futs = [q.submit("g", s) for s in (0, 1, 2)]
    mgr.close_session("g")
    q.drain()
    for f in futs:
        assert f.done()
        assert f.exception(0) is not None
        with pytest.raises(Exception):
            f.result(0)
    assert q.pending == 0


def test_future_result_timeout_raises_but_request_survives(graph, refs):
    q = RequestQueue(_mgr(graph))
    fut = q.submit("g", 4)
    with pytest.raises(TimeoutError):
        fut.result(0.001)                # nothing drained it yet
    q.drain()
    np.testing.assert_array_equal(fut.result(0), refs[4])


def test_stats_and_events_schema(graph):
    q = RequestQueue(_mgr(graph))
    q.submit("g", 0)
    q.drain()
    st = q.stats()
    for k in ("submitted", "completed", "timeouts", "degraded", "rejected",
              "coalesced", "waves", "pending"):
        assert k in st, k
    assert st["submitted"] == st["completed"] == 1


# ---------------------------------------------------------------------------
# epoch interplay: updates between waves keep serving current answers
# ---------------------------------------------------------------------------
def test_queue_serves_post_update_epoch(graph):
    """An edge update between drains swaps the prepared epoch; queued
    queries after the swap see the NEW graph."""
    mgr = _mgr(graph)
    q = RequestQueue(mgr)
    src = 0
    f0 = q.submit("g", src)
    q.drain()
    lv_before = f0.result(0)

    # add an edge from src to an unreached vertex
    INF = np.iinfo(np.int32).max
    far = int(np.argmax(lv_before == INF))
    assert lv_before[far] == INF
    report = mgr.update_edges("g", inserts=[(src, far)])
    assert report is not None and report.epoch == 1

    f1 = q.submit("g", src)
    q.drain()
    lv_after = f1.result(0)
    assert lv_after[far] == 1            # the new edge is live
