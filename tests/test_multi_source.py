"""BVSS-backed multi-source BFS: kernel-vs-oracle equivalence, per-column
oracle agreement (including disconnected sources), and the no-dense-
adjacency guarantee of the hot path."""
import inspect

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics.closeness import closeness_centrality
from repro.core import reference_bfs
from repro.core.multi_source import make_multi_source_bfs
from repro.graphs import from_edges, generators as gen
from repro.kernels import bvss_pull, bvss_spmm
from repro.kernels import ref

RNG = np.random.default_rng(0)

FAMILIES = {
    "rmat": gen.rmat(8, 8, seed=1),
    "grid": gen.grid2d(17, 19),
    "clustered": gen.clustered(8, 32, seed=4),
    "disconnected": from_edges(50, np.array([1, 2, 10]),
                               np.array([2, 3, 11])),
}


def u32(shape):
    return RNG.integers(0, 2 ** 32, shape, dtype=np.uint64).astype(np.uint32)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
@pytest.mark.parametrize("B,S", [(1, 1), (5, 3), (127, 8), (129, 9),
                                 (300, 130)])
def test_bvss_spmm_matches_ref(sigma, B, S):
    masks = jnp.asarray(u32((B, 32)))
    fb = jnp.asarray(u32((B, S)))
    got = np.asarray(bvss_spmm(masks, fb, sigma=sigma))
    want = np.asarray(ref.bvss_spmm_ref(masks, fb, sigma=sigma))
    assert got.shape == (B, 32 // sigma, 32, S)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("sigma", [4, 8])
def test_bvss_spmm_single_column_matches_bvss_pull(sigma):
    """With S=1 the stacked SpMM must reduce to the single-source VPU pull:
    counts > 0 == hits."""
    masks = jnp.asarray(u32((77, 32)))
    fb1 = jnp.asarray(u32((77,)))
    counts = np.asarray(bvss_spmm(masks, fb1[:, None], sigma=sigma))
    hits = np.asarray(bvss_pull(masks, fb1, sigma=sigma))
    np.testing.assert_array_equal(counts[..., 0] > 0, hits)


# ---------------------------------------------------------------------------
# engine vs host oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gname", sorted(FAMILIES))
def test_multi_source_oracle_agreement(gname):
    g = FAMILIES[gname]
    rng = np.random.default_rng(7)
    srcs = rng.integers(0, g.n, 5).astype(np.int32)
    f = make_multi_source_bfs(g, len(srcs))
    lv = np.asarray(f(jnp.asarray(srcs)))
    assert lv.shape == (g.n, len(srcs))
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[:, i], reference_bfs(g, int(s)),
                                      err_msg=f"column {i} source {s}")


def test_multi_source_kernel_and_jnp_agree():
    g = gen.rmat(8, 6, seed=3)
    srcs = jnp.asarray(np.array([0, 9, 100, 255], dtype=np.int32))
    lv_k = np.asarray(make_multi_source_bfs(g, 4, use_kernel=True)(srcs))
    lv_j = np.asarray(make_multi_source_bfs(g, 4, use_kernel=False)(srcs))
    np.testing.assert_array_equal(lv_k, lv_j)


def test_multi_source_duplicate_and_isolated_sources():
    # vertex 40 has no edges at all; duplicates must produce equal columns
    g = from_edges(50, np.array([1, 2, 10]), np.array([2, 3, 11]))
    srcs = np.array([1, 1, 40], dtype=np.int32)
    lv = np.asarray(make_multi_source_bfs(g, 3)(jnp.asarray(srcs)))
    np.testing.assert_array_equal(lv[:, 0], lv[:, 1])
    INF = np.int32(np.iinfo(np.int32).max)
    want = np.full(50, INF, dtype=np.int32)
    want[40] = 0
    np.testing.assert_array_equal(lv[:, 2], want)


def test_multi_source_hot_path_has_no_dense_adjacency():
    """The acceptance criterion: the BVSS multi-source engine must not
    materialise the O(n²/32) ``to_dense_bits`` adjacency."""
    import ast

    import repro.core.multi_source as ms
    tree = ast.parse(inspect.getsource(ms))
    names = {a.name for node in ast.walk(tree)
             if isinstance(node, (ast.Import, ast.ImportFrom))
             for a in node.names}
    used = {node.id for node in ast.walk(tree) if isinstance(node, ast.Name)}
    assert "to_dense_bits" not in names | used
    assert not hasattr(ms, "to_dense_bits")


def test_closeness_centrality_nonnegative_and_finite():
    g = gen.rmat(7, 8, seed=10)
    cc = closeness_centrality(g, np.arange(6, dtype=np.int32))
    assert cc.shape == (6,)
    assert (cc >= 0).all() and np.isfinite(cc).all()
