"""The CI ``verbs`` lane: EVERY GraphSession query verb runs against an
independent oracle on two fixture graphs (a scale-free digraph and a
shuffled road grid), and a verb without an oracle-parity check is a
FAILURE — new verbs must land with their oracle, never silently escape
the lane (PR 9, DESIGN §2.9)."""
import numpy as np
import pytest

from repro.graphs import generators as gen
from repro.kernels.ref import (betweenness_ref, closeness_ref,
                               connected_components_ref, eccentricity_ref,
                               normalize_labels, pagerank_ref, sssp_ref)
from repro.serve import GraphSession

INF = np.int32(np.iinfo(np.int32).max)

FIXTURES = {
    "kron": lambda: gen.rmat(7, 8, seed=11),
    "road": lambda: gen.grid2d(12, 12, shuffle=True, seed=12),
}

_cache: dict = {}


def _fixture(gname):
    """(graph, dyadic weights, weighted session) — one prepare per
    fixture for the whole lane."""
    if gname not in _cache:
        g = FIXTURES[gname]()
        rng = np.random.default_rng(13)
        w = (rng.integers(1, 128, g.m) / 32.0).astype(np.float32)
        _cache[gname] = (g, w, GraphSession(g, max_batch=4, weights=w))
    return _cache[gname]


# ---------------------------------------------------------------------------
# one oracle-parity check per verb; the lane FAILS on any verb that has
# no entry here (test_every_verb_has_an_oracle)
# ---------------------------------------------------------------------------
def _check_levels(g, w, sess):
    from repro.core import reference_bfs
    for src in (0, g.n // 2):
        np.testing.assert_array_equal(sess.levels(src),
                                      reference_bfs(g, src))


def _check_components(g, w, sess):
    np.testing.assert_array_equal(
        sess.components(), normalize_labels(connected_components_ref(g)))


def _check_eccentricity(g, w, sess):
    srcs = np.array([0, 1, g.n - 1])
    np.testing.assert_array_equal(sess.eccentricity_batch(srcs),
                                  eccentricity_ref(g.symmetrized, srcs))


def _check_betweenness(g, w, sess):
    srcs = np.array([0, g.n // 3])
    bc = sess.betweenness_batch(srcs)
    ref = betweenness_ref(g, srcs)
    np.testing.assert_allclose(bc, ref, rtol=1e-4, atol=1e-4)


def _check_closeness(g, w, sess):
    srcs = np.array([0, g.n // 2, g.n - 1])
    np.testing.assert_allclose(sess.closeness_batch(srcs),
                               closeness_ref(g, srcs), rtol=1e-9)


def _check_sssp(g, w, sess):
    srcs = [0, g.n // 2]
    dist = sess.sssp_batch(srcs)
    ref = sssp_ref(g, srcs, w)
    # dyadic weights: f32 path sums are exact, so demand equality
    np.testing.assert_array_equal(np.isinf(dist), np.isinf(ref))
    np.testing.assert_allclose(np.where(np.isinf(dist), 0.0, dist),
                               np.where(np.isinf(ref), 0.0, ref),
                               rtol=1e-6)
    # the single-source verb is the batch's width-1 twin
    d0 = sess.sssp(srcs[0])
    np.testing.assert_array_equal(np.isinf(d0), np.isinf(ref[0]))


def _check_pagerank(g, w, sess):
    pr = sess.pagerank(tol=1e-10, max_iter=500)
    ref = pagerank_ref(g)
    rel = np.max(np.abs(pr - ref) / np.maximum(np.abs(ref), 1e-30))
    assert rel <= 1e-6, f"pagerank max rel err {rel}"
    assert abs(pr.sum() - 1.0) < 1e-5


ORACLE_CHECKS = {
    "levels": _check_levels,
    "components": _check_components,
    "eccentricity": _check_eccentricity,
    "betweenness": _check_betweenness,
    "closeness": _check_closeness,
    "sssp": _check_sssp,
    "pagerank": _check_pagerank,
}


def test_every_verb_has_an_oracle():
    """The lane's completeness gate: a verb in GraphSession.VERBS with no
    oracle-parity check here is a failure, and a stale check for a
    removed verb is too."""
    missing = set(GraphSession.VERBS) - set(ORACLE_CHECKS)
    assert not missing, \
        f"GraphSession verbs without an oracle-parity check: {missing}"
    stale = set(ORACLE_CHECKS) - set(GraphSession.VERBS)
    assert not stale, f"oracle checks for unknown verbs: {stale}"


def test_verbs_tuple_is_canonical():
    """Every VERBS entry is a real callable on the session."""
    for verb in GraphSession.VERBS:
        assert callable(getattr(GraphSession, verb)), verb


# ---------------------------------------------------------------------------
# PR-10 signature conventions: singular verbs take ``src: int``, batched
# twins take ``sources`` as their first positional, sampling verbs take
# ``(k, *, seed)`` — enforced by inspect so a new verb cannot land with a
# divergent shape (deprecated aliases are exempt but must warn)
# ---------------------------------------------------------------------------
DEPRECATED_ALIASES = {
    "eccentricity": "eccentricity_batch",
    "betweenness": "betweenness_batch",
    "closeness": "closeness_batch",
    "centrality_sample": "closeness_sample",
}


def test_verb_signature_conventions():
    import inspect
    for family in GraphSession.VERBS:
        batch = getattr(GraphSession, f"{family}_batch", None)
        if batch is not None:
            params = list(inspect.signature(batch).parameters)
            assert params[:2] == ["self", "sources"], \
                f"{family}_batch must take `sources` first, got {params}"
        sample = getattr(GraphSession, f"{family}_sample", None)
        if sample is not None:
            sig = inspect.signature(sample)
            params = list(sig.parameters)
            assert params[:2] == ["self", "k"], \
                f"{family}_sample must take `k` first, got {params}"
            assert (sig.parameters["seed"].kind
                    is inspect.Parameter.KEYWORD_ONLY), \
                f"{family}_sample seed must be keyword-only"
    # singular source-taking verbs (not aliases) use `src: int`
    for name in ("levels", "sssp"):
        sig = inspect.signature(getattr(GraphSession, name))
        params = list(sig.parameters)
        assert params[:2] == ["self", "src"], (name, params)
        assert sig.parameters["src"].annotation in ("int", int), name


@pytest.mark.parametrize("old,new", sorted(DEPRECATED_ALIASES.items()))
def test_deprecated_aliases_warn_and_agree(old, new):
    g, w, sess = _fixture("kron")
    args = (3,) if old == "centrality_sample" \
        else (np.array([0, g.n // 2]),)
    with pytest.warns(DeprecationWarning, match=new):
        got = getattr(sess, old)(*args)
    want = getattr(sess, new)(*args)
    if isinstance(got, tuple):          # sample verbs: (sources, values)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# PR-10 incremental-maintenance oracle: apply_edge_updates must reproduce
# the FRESH build's bits (masks, row_ids, occupancy) for the mutated graph
# under the same ordering, and serve oracle-correct levels afterwards
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gname", sorted(FIXTURES))
def test_apply_edge_updates_bit_identical(gname):
    from repro import PrepareOptions, apply_edge_updates, from_edges, prepare
    from repro.core import build_bvss, reference_bfs
    from repro.graphs import src_of_edges

    g, w, _ = _fixture(gname)
    prep = prepare(g, options=PrepareOptions(w=512, seed=0))
    rng = np.random.default_rng(17)
    for round_i in range(3):
        # random inserts (may collide with existing: no-ops) + deletes
        # of real edges of the CURRENT graph, both in caller ids
        ins = sorted({(int(a), int(b))
                      for a, b in rng.integers(0, g.n, (6, 2)) if a != b})
        src_i = prep.inv[src_of_edges(prep.graph)]
        dst_i = prep.inv[prep.graph.indices]
        pick = rng.choice(len(src_i), size=min(4, len(src_i)),
                          replace=False)
        dels = sorted({(int(src_i[p]), int(dst_i[p])) for p in pick}
                      - set(ins))
        prep = apply_edge_updates(prep, inserts=ins, deletes=dels)

        # fresh-build oracle over the SAME ordering
        g_ord = prep.graph
        b2 = build_bvss(g_ord, sigma=prep.bvss.sigma)
        np.testing.assert_array_equal(prep.bvss.masks, b2.masks)
        np.testing.assert_array_equal(prep.bvss.row_ids, b2.row_ids)
        np.testing.assert_array_equal(prep.bvss.real_ptrs, b2.real_ptrs)
        assert prep.bvss.num_slices == b2.num_slices
        assert prep.epoch == round_i + 1

        # and the served levels match the mutated caller graph's oracle
        src_c = prep.inv[src_of_edges(g_ord)]
        dst_c = prep.inv[g_ord.indices]
        g_caller = from_edges(g.n, src_c, dst_c, dedup=True,
                              drop_loops=False)
        for s in (0, g.n // 2):
            np.testing.assert_array_equal(prep.levels(s),
                                          reference_bfs(g_caller, s))


@pytest.mark.parametrize("gname", sorted(FIXTURES))
@pytest.mark.parametrize("verb", GraphSession.VERBS)
def test_verb_oracle_parity(gname, verb):
    g, w, sess = _fixture(gname)
    ORACLE_CHECKS[verb](g, w, sess)
