"""Paper §5 static policy pipeline + mesh-native multi-source parity."""
import os
import subprocess
import sys

import numpy as np

from repro.core import reference_bfs
from repro.core.policy import (choose_update_scheme, parents_from_levels,
                               prepare)
from repro.graphs import generators as gen

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_prepared_pipeline_matches_oracle():
    for g in (gen.rmat(8, 10, seed=2), gen.grid2d(18, 18, shuffle=True)):
        pb = prepare(g, w=256)
        for src in (0, g.n // 2):
            np.testing.assert_array_equal(pb.levels(src),
                                          reference_bfs(g, src))


def test_update_scheme_policy():
    # high-divergence social graph -> lazy; ordered road graph -> eager
    from repro.core.bvss import build_bvss
    from repro.core.ordering import rcm
    g_soc = gen.rmat(9, 16, seed=1)
    g_road = gen.grid2d(24, 24)
    b_soc = build_bvss(g_soc)
    b_road = build_bvss(g_road.permute_fast(rcm(g_road)))
    assert choose_update_scheme(b_soc) == "blest_lazy"
    assert choose_update_scheme(b_road) == "blest"


def test_parents_valid_tree():
    g = gen.rmat(7, 8, seed=3)
    pb = prepare(g, w=128)
    lv = pb.levels(0)
    parents = parents_from_levels(g, lv)
    INF = np.iinfo(np.int32).max
    assert parents[0] == -1
    for u in range(g.n):
        if lv[u] not in (0, INF):
            p = parents[u]
            assert p >= 0 and lv[p] == lv[u] - 1
            # parent edge must exist
            assert u in g.indices[g.indptr[p]:g.indptr[p + 1]]


def test_sharded_multi_source_matches_single_device():
    """The fused mesh-native multi-source engine (one shard_map'd
    while_loop) must agree with the single-device BVSS SpMM engine AND the
    host oracle, column by column."""
    code = """
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare
from repro.core.multi_source import make_multi_source_bfs
from repro.distributed.bfs_dist import bfs_mesh
g = gen.rmat(8, 8, seed=5)
pb_s = prepare(g, w=256, mesh=bfs_mesh(4), engine="blest")
pb_1 = prepare(g, w=256, engine="blest")
srcs_orig = np.array([0, g.n // 3, g.n - 1, 7], dtype=np.int32)
f_s = make_multi_source_bfs(None, 4, problem=pb_s.problem)
f_1 = make_multi_source_bfs(None, 4, problem=pb_1.problem)
lv_s = np.asarray(f_s(pb_s.perm[srcs_orig].astype(np.int32)))
lv_1 = np.asarray(f_1(pb_1.perm[srcs_orig].astype(np.int32)))
np.testing.assert_array_equal(lv_s[pb_s.perm], lv_1[pb_1.perm])
for j, s in enumerate(srcs_orig):
    np.testing.assert_array_equal(lv_s[pb_s.perm][:, j],
                                  reference_bfs(g, int(s)))
print("ok")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
