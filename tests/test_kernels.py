"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (bit_spmm, bvss_pull, finalize_pack_sweep,
                           finalize_sweep)
from repro.kernels import ref

RNG = np.random.default_rng(0)


def u32(shape):
    return RNG.integers(0, 2 ** 32, shape, dtype=np.uint64).astype(np.uint32)


@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
@pytest.mark.parametrize("B", [1, 5, 127, 128, 129, 513])
@pytest.mark.parametrize("layout", ["lanes", "rows"])
def test_bvss_pull_sweep(sigma, B, layout):
    masks = jnp.asarray(u32((B, 32)))
    fb = jnp.asarray(u32((B,)))
    got = np.asarray(bvss_pull(masks, fb, sigma=sigma, layout=layout))
    want = np.asarray(ref.bvss_pull_ref(masks, fb, sigma=sigma))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile", [32, 128, 256])
def test_bvss_pull_tile_sweep(tile):
    masks = jnp.asarray(u32((300, 32)))
    fb = jnp.asarray(u32((300,)))
    got = np.asarray(bvss_pull(masks, fb, tile=tile))
    want = np.asarray(ref.bvss_pull_ref(masks, fb))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("R,C,S", [(8, 40, 3), (128, 128, 128),
                                   (200, 300, 70), (1, 32, 1),
                                   (130, 260, 129)])
def test_bit_spmm_sweep(R, C, S):
    W = (C + 31) // 32
    a = u32((R, W))
    keep = C - (W - 1) * 32
    if keep < 32:
        a[:, -1] &= np.uint32((1 << keep) - 1)
    x = RNG.integers(0, 2, (C, S)).astype(np.int8)
    got = np.asarray(bit_spmm(jnp.asarray(a), jnp.asarray(x)))
    want = np.asarray(ref.bit_spmm_ref(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(N=st.integers(1, 5000), lvl=st.integers(1, 100),
       seed=st.integers(0, 1000))
def test_finalize_sweep_property(N, lvl, seed):
    rng = np.random.default_rng(seed)
    marks = rng.integers(0, 2, N).astype(np.uint8)
    levels = np.where(rng.random(N) < 0.5, np.int32(2 ** 31 - 1),
                      rng.integers(0, lvl, N).astype(np.int32))
    g_lv, g_new = finalize_sweep(jnp.asarray(marks), jnp.asarray(levels), lvl)
    w_lv, w_new = ref.finalize_sweep_ref(jnp.asarray(marks),
                                         jnp.asarray(levels), lvl)
    np.testing.assert_array_equal(np.asarray(g_lv), np.asarray(w_lv))
    np.testing.assert_array_equal(np.asarray(g_new), np.asarray(w_new))
    # invariants: levels only decrease from INF, new implies mark
    new = np.asarray(g_new)
    assert (new <= (marks > 0)).all()
    assert (np.asarray(g_lv)[new] == lvl).all()


@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
@pytest.mark.parametrize("N", [1, 31, 257, 4000])
@pytest.mark.parametrize("mode", ["eager", "lazy"])
def test_finalize_pack_sweep_matches_inline_finalise(sigma, N, mode):
    """The fused finalise + frontier-pack + set-flag kernel must match the
    three inline jnp passes it replaces (ref.finalize_pack_ref)."""
    rng = np.random.default_rng(N * sigma)
    lvl = 2
    n_sets = (N + sigma - 1) // sigma
    n_fwords = (n_sets * sigma + 31) // 32
    levels = np.where(rng.random(N) < 0.5, np.int32(2 ** 31 - 1),
                      rng.integers(0, lvl + 1, N).astype(np.int32))
    marks = rng.integers(0, 2, N).astype(np.uint8)
    kw = dict(sigma=sigma, n_fwords=n_fwords, n_sets=n_sets)
    if mode == "lazy":
        kw["marks"] = jnp.asarray(marks)
    got = finalize_pack_sweep(jnp.asarray(levels), lvl, **kw)
    want = ref.finalize_pack_ref(jnp.asarray(levels), lvl, **kw)
    for name, (gt, wt) in zip(("levels", "fwords", "set_active"),
                              zip(got, want)):
        np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt),
                                      err_msg=name)
    # invariants: every set flagged active contains a new vertex, packed
    # word bits agree with the new mask
    lv_out, fwords, act = (np.asarray(x) for x in got)
    if mode == "lazy":
        new = (marks > 0) & (levels == np.int32(2 ** 31 - 1))
    else:
        new = levels == lvl
    bits = np.zeros(n_fwords * 32, dtype=bool)
    bits[:N] = new
    packed = np.packbits(bits.reshape(n_fwords, 32), axis=1,
                         bitorder="little").view("<u4").ravel()
    np.testing.assert_array_equal(fwords, packed)
    sbits = np.zeros(n_sets * sigma, dtype=bool)
    sbits[:N] = new
    np.testing.assert_array_equal(act, sbits.reshape(n_sets, sigma).any(1))


@settings(max_examples=10, deadline=None)
@given(N=st.integers(1, 3000), lvl=st.integers(1, 60),
       seed=st.integers(0, 1000))
def test_finalize_pack_sweep_property(N, lvl, seed):
    rng = np.random.default_rng(seed)
    sigma = 8
    n_sets = (N + sigma - 1) // sigma
    n_fwords = (n_sets * sigma + 31) // 32
    marks = rng.integers(0, 2, N).astype(np.uint8)
    levels = np.where(rng.random(N) < 0.5, np.int32(2 ** 31 - 1),
                      rng.integers(0, lvl, N).astype(np.int32))
    got = finalize_pack_sweep(jnp.asarray(levels), lvl, sigma=sigma,
                              n_fwords=n_fwords, n_sets=n_sets,
                              marks=jnp.asarray(marks))
    want = ref.finalize_pack_ref(jnp.asarray(levels), lvl, sigma=sigma,
                                 n_fwords=n_fwords, n_sets=n_sets,
                                 marks=jnp.asarray(marks))
    for gt, wt in zip(got, want):
        np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))
