"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import bit_spmm, bvss_pull, finalize_sweep
from repro.kernels import ref

RNG = np.random.default_rng(0)


def u32(shape):
    return RNG.integers(0, 2 ** 32, shape, dtype=np.uint64).astype(np.uint32)


@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
@pytest.mark.parametrize("B", [1, 5, 127, 128, 129, 513])
@pytest.mark.parametrize("layout", ["lanes", "rows"])
def test_bvss_pull_sweep(sigma, B, layout):
    masks = jnp.asarray(u32((B, 32)))
    fb = jnp.asarray(u32((B,)))
    got = np.asarray(bvss_pull(masks, fb, sigma=sigma, layout=layout))
    want = np.asarray(ref.bvss_pull_ref(masks, fb, sigma=sigma))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile", [32, 128, 256])
def test_bvss_pull_tile_sweep(tile):
    masks = jnp.asarray(u32((300, 32)))
    fb = jnp.asarray(u32((300,)))
    got = np.asarray(bvss_pull(masks, fb, tile=tile))
    want = np.asarray(ref.bvss_pull_ref(masks, fb))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("R,C,S", [(8, 40, 3), (128, 128, 128),
                                   (200, 300, 70), (1, 32, 1),
                                   (130, 260, 129)])
def test_bit_spmm_sweep(R, C, S):
    W = (C + 31) // 32
    a = u32((R, W))
    keep = C - (W - 1) * 32
    if keep < 32:
        a[:, -1] &= np.uint32((1 << keep) - 1)
    x = RNG.integers(0, 2, (C, S)).astype(np.int8)
    got = np.asarray(bit_spmm(jnp.asarray(a), jnp.asarray(x)))
    want = np.asarray(ref.bit_spmm_ref(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(N=st.integers(1, 5000), lvl=st.integers(1, 100),
       seed=st.integers(0, 1000))
def test_finalize_sweep_property(N, lvl, seed):
    rng = np.random.default_rng(seed)
    marks = rng.integers(0, 2, N).astype(np.uint8)
    levels = np.where(rng.random(N) < 0.5, np.int32(2 ** 31 - 1),
                      rng.integers(0, lvl, N).astype(np.int32))
    g_lv, g_new = finalize_sweep(jnp.asarray(marks), jnp.asarray(levels), lvl)
    w_lv, w_new = ref.finalize_sweep_ref(jnp.asarray(marks),
                                         jnp.asarray(levels), lvl)
    np.testing.assert_array_equal(np.asarray(g_lv), np.asarray(w_lv))
    np.testing.assert_array_equal(np.asarray(g_new), np.asarray(w_new))
    # invariants: levels only decrease from INF, new implies mark
    new = np.asarray(g_new)
    assert (new <= (marks > 0)).all()
    assert (np.asarray(g_lv)[new] == lvl).all()
