"""API-surface snapshot: the ``repro`` façade is a compatibility contract.

``tests/api_surface.txt`` is the checked-in rendering of every name the
façade exports — kind, base classes, and the full parameter shape of every
public callable (including public methods one level deep).  The CI lint
job runs this test, so an accidental export, removal, or signature change
fails fast; a DELIBERATE change regenerates the snapshot:

    PYTHONPATH=src python -m tests.test_api_surface --update

The rendering is deliberately annotation- and default-VALUE-free (names,
order, and parameter kinds only) so it is stable across Python versions —
the tier-1 matrix runs 3.10 and 3.12 against the same snapshot.
"""
from __future__ import annotations

import inspect
import os
import sys

SNAPSHOT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "api_surface.txt")


def _params(fn) -> str:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return "(...)"
    parts = []
    for p in sig.parameters.values():
        if p.name == "self":
            continue
        if p.kind is inspect.Parameter.VAR_POSITIONAL:
            parts.append(f"*{p.name}")
            continue
        if p.kind is inspect.Parameter.VAR_KEYWORD:
            parts.append(f"**{p.name}")
            continue
        if (p.kind is inspect.Parameter.KEYWORD_ONLY
                and (not parts or not parts[-1].startswith("*"))
                and "*" not in parts):
            parts.append("*")
        parts.append(p.name + ("=?" if p.default is not p.empty else ""))
    return "(" + ", ".join(parts) + ")"


def _class_lines(name: str, cls: type) -> list[str]:
    bases = [b.__name__ for b in cls.__bases__ if b is not object]
    head = f"{name}: class" + (f"({', '.join(bases)})" if bases else "")
    lines = [head + " " + _params(cls)]
    for attr in sorted(vars(cls)):
        if attr.startswith("_"):
            continue
        member = inspect.getattr_static(cls, attr)
        if isinstance(member, (staticmethod, classmethod)):
            member = member.__func__
        if isinstance(member, property):
            lines.append(f"{name}.{attr}: property")
        elif callable(member):
            lines.append(f"{name}.{attr}: method {_params(member)}")
    return lines


def render_surface() -> str:
    import repro
    lines = [
        "# repro public API surface (names + parameter shapes).",
        "# Regenerate DELIBERATELY after an intended change:",
        "#   PYTHONPATH=src python -m tests.test_api_surface --update",
    ]
    for name in sorted(repro.__all__):
        obj = getattr(repro, name)
        if name == "__version__":
            lines.append("__version__: str")
        elif name == "VERBS":
            lines.append(f"VERBS: tuple {tuple(obj)}")
        elif inspect.isclass(obj):
            lines.extend(_class_lines(name, obj))
        elif callable(obj):
            lines.append(f"{name}: function {_params(obj)}")
        else:
            lines.append(f"{name}: {type(obj).__name__}")
    return "\n".join(lines) + "\n"


def test_facade_exports_resolve():
    import repro
    for name in repro.__all__:
        assert getattr(repro, name, None) is not None, name
    assert len(set(repro.__all__)) == len(repro.__all__)


def test_api_surface_matches_snapshot():
    with open(SNAPSHOT) as f:
        want = f.read()
    got = render_surface()
    assert got == want, (
        "the repro façade's API surface diverged from "
        "tests/api_surface.txt — if the change is intended, regenerate "
        "with: PYTHONPATH=src python -m tests.test_api_surface --update\n"
        + "\n".join(_diff(want, got)))


def _diff(want: str, got: str) -> list[str]:
    import difflib
    return list(difflib.unified_diff(want.splitlines(), got.splitlines(),
                                     "api_surface.txt", "current",
                                     lineterm="", n=1))[:40]


if __name__ == "__main__":
    if "--update" in sys.argv:
        with open(SNAPSHOT, "w") as f:
            f.write(render_surface())
        print(f"wrote {SNAPSHOT}")
    else:
        print(render_surface(), end="")
